"""DDPM substrate: noise schedules, forward process, training loss, and the
respaced ancestral sampler used by the paper (T_train=1000 linear schedule;
inference respaced to 100/250 steps as in DiT / TQ-DiT §IV-A).

All samplers thread the TGQ timestep-group index through the model context
(``ctx.with_tgroup(g)``) so time-grouped quantizers select the right
parameter set at each step — the inference-side half of the paper's TGQ.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.ctx import FPContext

_FP = FPContext()


@dataclasses.dataclass(frozen=True)
class DiffusionCfg:
    T: int = 1000                  # training timesteps
    beta_start: float = 1e-4
    beta_end: float = 0.02
    schedule: str = "linear"       # linear | cosine
    tgq_groups: int = 10           # G in the paper (group index fed to ctx)


def make_schedule(cfg: DiffusionCfg):
    """Returns dict of (T,) float32 schedule arrays."""
    if cfg.schedule == "linear":
        betas = np.linspace(cfg.beta_start, cfg.beta_end, cfg.T, dtype=np.float64)
    elif cfg.schedule == "cosine":
        s = 0.008
        ts = np.arange(cfg.T + 1, dtype=np.float64) / cfg.T
        f = np.cos((ts + s) / (1 + s) * np.pi / 2) ** 2
        betas = np.clip(1 - f[1:] / f[:-1], 0, 0.999)
    else:
        raise ValueError(cfg.schedule)
    alphas = 1.0 - betas
    abar = np.cumprod(alphas)
    abar_prev = np.concatenate([[1.0], abar[:-1]])
    post_var = betas * (1.0 - abar_prev) / (1.0 - abar)   # q(x_{t-1}|x_t,x_0)
    j = lambda a: jnp.asarray(a, jnp.float32)
    return {
        "betas": j(betas), "alphas": j(alphas), "abar": j(abar),
        "abar_prev": j(abar_prev),
        "sqrt_abar": j(np.sqrt(abar)),
        "sqrt_1m_abar": j(np.sqrt(1 - abar)),
        "post_var": j(post_var),
        "post_logvar": j(np.log(np.maximum(post_var, 1e-20))),
    }


# ---------------------------------------------------------------------------
# forward process + loss
# ---------------------------------------------------------------------------
def q_sample(sched, x0, t, noise):
    """x_t = sqrt(abar_t) x0 + sqrt(1-abar_t) eps; t: (B,) int32."""
    shape = (-1,) + (1,) * (x0.ndim - 1)
    a = sched["sqrt_abar"][t].reshape(shape)
    b = sched["sqrt_1m_abar"][t].reshape(shape)
    return a * x0 + b * noise


def ddpm_loss(eps_fn: Callable, sched, x0, t, y, key):
    """E ||eps - eps_theta(x_t, t)||^2 (Eq. 11)."""
    noise = jax.random.normal(key, x0.shape, x0.dtype)
    xt = q_sample(sched, x0, t, noise)
    pred = eps_fn(xt, t, y)
    return jnp.mean(jnp.square(pred - noise))


# ---------------------------------------------------------------------------
# respacing (DDPM T=1000 -> 100/250 inference steps)
# ---------------------------------------------------------------------------
def respaced_timesteps(T: int, steps: int) -> np.ndarray:
    """Evenly respaced subset of {0..T-1}, descending (sampling order)."""
    ts = np.linspace(0, T - 1, steps).round().astype(np.int64)
    return np.unique(ts)[::-1].copy()


def respaced_schedule(sched, use_ts: np.ndarray):
    """Rebuild alphas/betas over the respaced chain (Nichol & Dhariwal)."""
    abar = np.asarray(sched["abar"])[use_ts[::-1]]        # ascending
    abar_prev = np.concatenate([[1.0], abar[:-1]])
    alphas = abar / abar_prev
    betas = 1.0 - alphas
    post_var = betas * (1.0 - abar_prev) / (1.0 - abar)
    j = lambda a: jnp.asarray(a, jnp.float32)
    return {
        "betas": j(betas), "alphas": j(alphas), "abar": j(abar),
        "abar_prev": j(abar_prev),
        "sqrt_abar": j(np.sqrt(abar)), "sqrt_1m_abar": j(np.sqrt(1 - abar)),
        "post_var": j(post_var),
        "post_logvar": j(np.log(np.maximum(post_var, 1e-20))),
    }


def tgroup_of(t, T: int, G: int):
    """TGQ group index g(t) = floor(t*G/T) for original-chain timestep t."""
    return jnp.clip((t * G) // T, 0, G - 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# ancestral sampler
# ---------------------------------------------------------------------------
def ddpm_sample(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y, key,
                steps: Optional[int] = None, ctx=_FP,
                clip_x0: Optional[float] = None):
    """Ancestral DDPM sampling with respacing.

    eps_fn(x, t, y, ctx) -> predicted noise, where t is the ORIGINAL-chain
    timestep (the model was trained on it). The context receives the TGQ
    group of t at every step.
    Returns x_0 samples of ``shape``.
    """
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)             # descending
    rsched = respaced_schedule(sched, use_ts)
    n = len(use_ts)
    use_ts_j = jnp.asarray(use_ts.copy(), jnp.int32)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape, jnp.float32)

    def step(carry, i):
        x, key = carry
        key, kn = jax.random.split(key)
        t_orig = use_ts_j[i]                              # original-chain t
        idx = n - 1 - i                                   # respaced index (asc)
        tb = jnp.full((shape[0],), t_orig, jnp.int32)
        g = tgroup_of(t_orig, cfg.T, cfg.tgq_groups)
        eps = eps_fn(x, tb, y, ctx.with_tgroup(g))

        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]

        # predict x0, clip, then q(x_{t-1} | x_t, x0) mean
        x0 = (x - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(abar)
        if clip_x0 is not None:
            x0 = jnp.clip(x0, -clip_x0, clip_x0)
        mean = (jnp.sqrt(abar_prev) * beta / (1 - abar) * x0
                + jnp.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        noise = jax.random.normal(kn, shape, jnp.float32)
        nonzero = (idx > 0).astype(jnp.float32)
        x = mean + nonzero * jnp.sqrt(rsched["post_var"][idx]) * noise
        return (x, key), None

    (x, _), _ = jax.lax.scan(step, (x, key), jnp.arange(n))
    return x


def request_keys(seeds) -> jnp.ndarray:
    """(B,) per-request integer seeds -> (B, 2) uint32 PRNG keys.

    Serving draws ALL of a request's noise from its own key (see
    ``ddpm_sample_paired``), so a request's sample depends only on its
    seed — never on which microbatch slot, padding, or device shard it
    happens to land in.
    """
    return jax.vmap(jax.random.PRNGKey)(jnp.asarray(seeds, jnp.uint32))


def ddpm_sample_paired(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y,
                       seeds, guidance, *, null_label: int,
                       steps: Optional[int] = None, ctx=_FP,
                       clip_x0: Optional[float] = None):
    """Serving-path ancestral sampler: CFG-paired forwards, per-request keys.

    Two differences from :func:`ddpm_sample` (the research sampler):

    - **Per-request noise.** Every request carries its own PRNG seed; all
      noise is drawn per SAMPLE as ``normal(fold_in(PRNGKey(seed), i))``
      (``i`` = scan position, ``i = n`` for the initial latent). A
      request's output is therefore bit-identical no matter how the
      scheduler packs it into microbatches, how much padding rides along,
      or how the batch is sharded across devices — the property the
      sharded-vs-single-device serving tests pin down.
    - **Classifier-free guidance in one batched forward.** Each step runs
      the model ONCE on a 2B batch — the conditional half ``y`` stacked on
      the unconditional half ``null_label`` — and combines
      ``eps = eps_u + s * (eps_c - eps_u)`` with a PER-REQUEST scale
      ``s = guidance[b]`` (s=1: plain conditional, s=0: unconditional).

    The TGQ timestep group is threaded through ``ctx.with_tgroup`` exactly
    as in ``ddpm_sample``, so quantized serving (fused int8 kernels with
    stacked per-group params) compiles once across all groups.

    y: (B,) int labels; seeds: (B,) int per-request seeds;
    guidance: (B,) float CFG scales. Returns x_0 samples of ``shape``.
    """
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)             # descending
    rsched = respaced_schedule(sched, use_ts)
    n = len(use_ts)
    use_ts_j = jnp.asarray(use_ts.copy(), jnp.int32)
    B = shape[0]

    keys = request_keys(seeds)
    sshape = tuple(shape[1:])                             # per-sample shape

    def draw(salt):
        """Per-sample noise: each request's key, folded with the step."""
        return jax.vmap(lambda k: jax.random.normal(
            jax.random.fold_in(k, salt), sshape, jnp.float32))(keys)

    gsc = jnp.asarray(guidance, jnp.float32).reshape(
        (B,) + (1,) * (len(shape) - 1))
    yy = jnp.concatenate([jnp.asarray(y, jnp.int32),
                          jnp.full((B,), null_label, jnp.int32)])

    x = draw(n)                                           # initial latent

    def step(x, i):
        t_orig = use_ts_j[i]                              # original-chain t
        idx = n - 1 - i                                   # respaced index (asc)
        tb = jnp.full((2 * B,), t_orig, jnp.int32)
        g = tgroup_of(t_orig, cfg.T, cfg.tgq_groups)
        eps2 = eps_fn(jnp.concatenate([x, x]), tb, yy, ctx.with_tgroup(g))
        eps_c, eps_u = jnp.split(eps2, 2)
        eps = eps_u + gsc * (eps_c - eps_u)

        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]

        x0 = (x - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(abar)
        if clip_x0 is not None:
            x0 = jnp.clip(x0, -clip_x0, clip_x0)
        mean = (jnp.sqrt(abar_prev) * beta / (1 - abar) * x0
                + jnp.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        noise = draw(i)
        nonzero = (idx > 0).astype(jnp.float32)
        x = mean + nonzero * jnp.sqrt(rsched["post_var"][idx]) * noise
        return x, None

    x, _ = jax.lax.scan(step, x, jnp.arange(n))
    return x


def ddpm_sample_python(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y,
                       key, steps: Optional[int] = None, ctx=_FP,
                       clip_x0: Optional[float] = None):
    """Python-loop sampler (for calibration capture: the PTQ engine's eager
    contexts record per-step activations, which lax.scan would hide)."""
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)
    rsched = respaced_schedule(sched, use_ts)
    rsched = jax.tree.map(np.asarray, rsched)
    n = len(use_ts)

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape, jnp.float32)
    for i in range(n):
        key, kn = jax.random.split(key)
        t_orig = int(use_ts[i])
        idx = n - 1 - i
        tb = jnp.full((shape[0],), t_orig, jnp.int32)
        g = int(tgroup_of(jnp.int32(t_orig), cfg.T, cfg.tgq_groups))
        eps = eps_fn(x, tb, y, ctx.with_tgroup(g))

        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]
        x0 = (x - np.sqrt(1 - abar) * eps) / np.sqrt(abar)
        if clip_x0 is not None:
            x0 = jnp.clip(x0, -clip_x0, clip_x0)
        mean = (np.sqrt(abar_prev) * beta / (1 - abar) * x0
                + np.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        if idx > 0:
            x = mean + np.sqrt(rsched["post_var"][idx]) * jax.random.normal(
                kn, shape, jnp.float32)
        else:
            x = mean
    return x


def collect_xt_dataset(eps_fn: Callable, cfg: DiffusionCfg, sched, shape, y,
                       key, steps: int, want_ts: np.ndarray, ctx=_FP):
    """Run the sampler and harvest (x_t, t, y) tuples at the requested
    original-chain timesteps — Phase 1 of Algorithm 1 (calibration set
    built from the model's OWN sampling trajectory, matching Q-Diffusion/
    TQ-DiT protocol).
    """
    steps = steps or cfg.T
    use_ts = respaced_timesteps(cfg.T, steps)
    rsched = jax.tree.map(np.asarray, respaced_schedule(sched, use_ts))
    n = len(use_ts)
    want = set(int(t) for t in want_ts)
    out = []

    key, k0 = jax.random.split(key)
    x = jax.random.normal(k0, shape, jnp.float32)
    for i in range(n):
        key, kn = jax.random.split(key)
        t_orig = int(use_ts[i])
        idx = n - 1 - i
        if t_orig in want:
            out.append((np.asarray(x), t_orig, np.asarray(y)))
        tb = jnp.full((shape[0],), t_orig, jnp.int32)
        g = int(tgroup_of(jnp.int32(t_orig), cfg.T, cfg.tgq_groups))
        eps = eps_fn(x, tb, y, ctx.with_tgroup(g))
        abar = rsched["abar"][idx]
        abar_prev = rsched["abar_prev"][idx]
        beta = rsched["betas"][idx]
        alpha = rsched["alphas"][idx]
        x0 = (x - np.sqrt(1 - abar) * eps) / np.sqrt(abar)
        mean = (np.sqrt(abar_prev) * beta / (1 - abar) * x0
                + np.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        if idx > 0:
            x = mean + np.sqrt(rsched["post_var"][idx]) * jax.random.normal(
                kn, shape, jnp.float32)
        else:
            x = mean
    return out
