"""Recipe auto-search launcher — a thin CLI over ``repro.autotune``.

Expands a declarative search space (bits x method x TGQ group counts,
plus AdaTSQ-style mixed-precision mean-bit budgets), runs every trial
through ``quantize()`` and the two-stage evaluator, and emits the
quality-vs-throughput Pareto frontier: ``BENCH_autotune.json`` +
``report.md`` + one saved ``QuantArtifact`` per trial under ``--out``.

The sweep is RESUMABLE: trials are keyed by recipe content hash in
``<out>/ledger.jsonl``, so re-running the same command after a kill
cache-hits every completed trial (``--assert-resumed`` verifies that:
zero recomputed trials and a frontier identical to the one already on
disk). ``--max-new-stage1 N`` stops the run after N newly-calibrated
trials — the deterministic stand-in for ``kill -9`` in CI.

Usage (the ``make autotune-smoke`` protocol):
  PYTHONPATH=src python -m repro.launch.autotune --arch tiny --out /tmp/at \
      --bits w8a8,w4a4 --groups default,5 --budgets 5,6 --max-new-stage1 3
  PYTHONPATH=src python -m repro.launch.autotune --arch tiny --out /tmp/at \
      --bits w8a8,w4a4 --groups default,5 --budgets 5,6 --assert-endpoints
  PYTHONPATH=src python -m repro.launch.autotune --arch tiny --out /tmp/at \
      --bits w8a8,w4a4 --groups default,5 --budgets 5,6 \
      --assert-endpoints --assert-resumed

``--arch bench`` sweeps the table-benchmark DiT from
``benchmarks/common.py`` (cached training checkpoint, honors
REPRO_DIT_STEPS); ``--arch tiny`` trains (once, cached under
experiments/) a 2-layer DiT small enough for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import pickle
import sys
import time


def tiny_dit(train_steps: int, exp_dir: str):
    """A 2-layer DiT trained briefly on the synthetic latents — small
    enough for the CI smoke but REAL enough that quantization error
    orders FD the right way (an untrained net scores every context the
    same). Cached like the bench checkpoint."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.diffusion import make_schedule, q_sample
    from repro.models import DiTCfg, dit_apply, dit_init
    from repro.optim import adamw, apply_updates, cosine_schedule
    from repro.quant import eval as qeval

    cfg = DiTCfg(img_size=8, in_ch=4, patch=2, d_model=64, n_layers=2,
                 n_heads=4, n_classes=8)
    from repro.diffusion import DiffusionCfg
    dif = DiffusionCfg(T=1000, tgq_groups=10)
    os.makedirs(exp_dir, exist_ok=True)
    path = os.path.join(exp_dir, f"dit_tiny_{train_steps}.pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return cfg, dif, pickle.load(f)

    key = jax.random.PRNGKey(0)
    params = dit_init(key, cfg)
    sched = make_schedule(dif)
    pipe = qeval.make_pipeline(cfg)
    opt = adamw(cosine_schedule(2e-3, 20, train_steps), weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(p, o, x0, t, y, noise):
        def loss_fn(p):
            xt = q_sample(sched, x0, t, noise)
            eps = dit_apply(p, cfg, xt, t, y)
            return jnp.mean(jnp.square(eps - noise))
        l, g = jax.value_and_grad(loss_fn)(p)
        u, o = opt.update(g, o, p)
        return l, apply_updates(p, u), o

    t0 = time.time()
    for i in range(train_steps):
        key, k1, k2, k3 = jax.random.split(key, 4)
        x0, y = pipe.sample(32, k1)
        t = jax.random.randint(k2, (32,), 0, dif.T)
        noise = jax.random.normal(k3, x0.shape)
        l, params, opt_state = step(params, opt_state, x0, t, y, noise)
        if i % 100 == 0 or i == train_steps - 1:
            print(f"  [tiny-train] step {i} loss {float(l):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    host = jax.tree.map(np.asarray, params)
    with open(path, "wb") as f:
        pickle.dump(host, f)
    return cfg, dif, host


def _parse_groups(s: str):
    out = []
    for tok in s.split(","):
        tok = tok.strip()
        out.append(None if tok in ("default", "none", "") else int(tok))
    return tuple(out)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Recipe auto-search emitting the quality-vs-"
                    "throughput Pareto frontier (resumable).")
    ap.add_argument("--out", required=True,
                    help="sweep directory (ledger + artifacts + report)")
    ap.add_argument("--arch", choices=("bench", "tiny"), default="bench")
    ap.add_argument("--train-steps", type=int, default=200,
                    help="tiny arch: training steps for the cached model")
    ap.add_argument("--bits", default="w8a8,w6a6,w4a4")
    ap.add_argument("--methods", default="range")
    ap.add_argument("--groups", default="default",
                    help="comma list of TGQ group counts; 'default' "
                         "inherits the DiffusionCfg's")
    ap.add_argument("--budgets", default="",
                    help="comma list of mean-bit budgets for AdaTSQ-style "
                         "mixed trials (empty: uniform only)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=12,
                    help="stage-2 sampling steps")
    ap.add_argument("--n-gen", type=int, default=64)
    ap.add_argument("--gen-batch", type=int, default=32)
    ap.add_argument("--n-real", type=int, default=512)
    ap.add_argument("--n-mse", type=int, default=64)
    ap.add_argument("--prune-factor", type=float, default=50.0)
    ap.add_argument("--keep-at-least", type=int, default=2)
    ap.add_argument("--max-new-stage1", type=int, default=None,
                    help="stop after N newly-calibrated trials (the "
                         "deterministic kill for resume testing)")
    ap.add_argument("--assert-endpoints", action="store_true",
                    help="fail unless the frontier is non-empty, shows a "
                         "strict quality/throughput trade-off, its "
                         "fastest point is w4a4 and it contains a w8a8 "
                         "point")
    ap.add_argument("--assert-resumed", action="store_true",
                    help="fail unless this run recomputed nothing and "
                         "reproduced the frontier already on disk")
    args = ap.parse_args()

    from repro.autotune import EvalConfig, SearchSpace, expand, \
        load_trial_artifact, run_autotune

    exp = os.environ.get(
        "REPRO_EXP_DIR",
        os.path.join(os.path.dirname(__file__), "..", "..", "..",
                     "experiments"))
    if args.arch == "bench":
        from benchmarks.common import DIF, trained_dit
        model_cfg, params = trained_dit()
        dif_cfg = DIF
    else:
        model_cfg, dif_cfg, params = tiny_dit(args.train_steps, exp)

    space = SearchSpace(
        bits=tuple(b.strip() for b in args.bits.split(",") if b.strip()),
        methods=tuple(m.strip() for m in args.methods.split(",")
                      if m.strip()),
        tgq_groups=_parse_groups(args.groups),
        bit_budgets=tuple(float(b) for b in args.budgets.split(",")
                          if b.strip()),
        seed=args.seed)
    ecfg = EvalConfig(
        steps=args.steps, n_gen=args.n_gen, gen_batch=args.gen_batch,
        n_real=args.n_real, n_mse=args.n_mse,
        prune_factor=args.prune_factor, keep_at_least=args.keep_at_least)

    trials = expand(space)
    print(f"[autotune] {len(trials)} trials -> {args.out}", flush=True)

    bench_path = os.path.join(args.out, "BENCH_autotune.json")
    prior_frontier = None
    if args.assert_resumed and os.path.exists(bench_path):
        with open(bench_path) as f:
            prior_frontier = json.load(f)["frontier"]

    result = run_autotune(params, model_cfg, dif_cfg, space, ecfg,
                          args.out, max_new_stage1=args.max_new_stage1)
    if result.stopped_early:
        print(f"[autotune] stopped early: {result.recomputed} new trials "
              f"calibrated, ledger at {args.out}/ledger.jsonl resumes "
              "them", flush=True)
        return

    print(f"[autotune] done: {len(result.records)} trials "
          f"({result.pruned} pruned, {result.cache_hits} cache hits, "
          f"{result.recomputed} newly calibrated)", flush=True)
    for p in result.frontier:
        print(f"  frontier: {p['label']:<14} req/s={p['req_per_s']:9.2f} "
              f"FD={p['FD']:8.3f} -> {p['artifact']}", flush=True)

    def fail(msg: str) -> None:
        print(f"[autotune] ASSERTION FAILED: {msg}", file=sys.stderr,
              flush=True)
        raise SystemExit(1)

    # every frontier artifact must actually load (acceptance: the frontier
    # is a set of DEPLOYABLE artifacts, not just scores)
    by_key = {r["key"]: r for r in result.records}
    for p in result.frontier:
        art = load_trial_artifact(args.out, by_key[p["key"]])
        if art is None:
            fail(f"frontier artifact {p['artifact']} failed to load")

    if args.assert_endpoints:
        if not result.frontier:
            fail("empty frontier")
        if not result.strict_tradeoff:
            fail("frontier is not a strict quality-vs-throughput "
                 "trade-off")
        fastest = result.frontier[0]
        if fastest.get("bits") != "w4a4":
            fail(f"fastest frontier point is {fastest['label']}, "
                 "expected a w4a4 recipe")
        if not any(p.get("bits") == "w8a8" for p in result.frontier):
            fail("no w8a8 (max-quality) point on the frontier")
        print("[autotune] endpoint asserts passed", flush=True)

    if args.assert_resumed:
        if result.recomputed != 0:
            fail(f"resume recomputed {result.recomputed} trials")
        if result.cache_hits != len(trials):
            fail(f"resume cache-hit {result.cache_hits}/{len(trials)} "
                 "trials")
        if prior_frontier is not None and prior_frontier != result.frontier:
            fail("resumed frontier differs from the one on disk")
        print("[autotune] resume asserts passed "
              f"({result.cache_hits} cache hits, 0 recomputed)",
              flush=True)


if __name__ == "__main__":
    main()
