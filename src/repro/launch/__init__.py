"""Launch layer: production meshes, multi-pod dry-run, train/serve entry
points. NOTE: dryrun must be run as a module (python -m repro.launch.dryrun)
— it sets XLA_FLAGS before jax initializes."""
from repro.launch.mesh import (make_production_mesh, make_debug_mesh,
                               make_serving_mesh, HW)
