import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import pulls in jax: the
# dry-run needs 512 placeholder devices so jax.make_mesh can build the
# production meshes. Everything below is ordinary code.

# Multi-pod dry-run: .lower().compile() every (arch x input-shape x mesh)
# cell on the 16x16 single-pod and 2x16x16 multi-pod meshes, and dump
# memory_analysis / cost_analysis / collective stats per cell.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun                 # full matrix
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
#       --shape decode_32k --mesh multi
#   ... --out experiments/dryrun.json

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax


def run_cell(arch: str, shape_id: str, mesh_kind: str) -> Dict[str, Any]:
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.launch.hlo_stats import collective_stats

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.perf_counter()
    cell = build_cell(arch, shape_id, mesh)
    with mesh:
        jitted = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                         donate_argnums=cell["donate_argnums"])
        lowered = jitted.lower(*cell["args"])
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    colls = collective_stats(txt)

    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_id, "mesh": mesh_kind,
        "meta": cell["meta"],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": cost.get("flops", 0.0) if cost else None,
        "bytes_accessed_per_device": cost.get("bytes accessed", 0.0)
        if cost else None,
        "collectives": colls,
        "collective_bytes_per_device": sum(v["bytes"] for v in colls.values()),
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            rec[k] = getattr(mem, k, None)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=("single", "multi",
                                                       "both"))
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS, cells

    archs = [args.arch] if args.arch else list(ARCHS)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("ok", True)}

    for arch in archs:
        for shape_id, _ in cells(arch):
            if args.shape and shape_id != args.shape:
                continue
            for mk in meshes:
                if (arch, shape_id, mk) in done:
                    continue
                tag = f"{arch} x {shape_id} x {mk}"
                try:
                    rec = run_cell(arch, shape_id, mk)
                    rec["ok"] = True
                    gb = (rec.get("argument_size_in_bytes") or 0) / 2**30
                    tmp = (rec.get("temp_size_in_bytes") or 0) / 2**30
                    print(f"[OK]   {tag}: compile={rec['compile_s']}s "
                          f"args={gb:.2f}GiB temp={tmp:.2f}GiB "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"coll/dev={rec['collective_bytes_per_device']/2**20:.1f}MiB",
                          flush=True)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    rec = {"arch": arch, "shape": shape_id, "mesh": mk,
                           "ok": False, "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[FAIL] {tag}: {rec['error']}", flush=True)
                results.append(rec)
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells compiled. -> {args.out}")


if __name__ == "__main__":
    main()
