"""Production mesh builders.

Single pod: 256 chips as (16 data, 16 model). Multi-pod: 2 pods x 256 =
512 chips as (2 pod, 16 data, 16 model), with the "pod" axis crossing the
DCN boundary (collectives on it are costed at DCN, not ICI, bandwidth in
the roofline).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; tests see the
real 1-CPU backend).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices the backend actually has."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(data: int | None = None):
    """Data-parallel serving mesh: ``data`` devices (default: all visible)
    on the "data" axis, model axis 1. The serving engine replicates params
    and shards microbatches on "data" via shard_map — the DiT models in
    this repo fit on one chip, so serving scales out, not up."""
    data = data or jax.device_count()
    return jax.make_mesh((data, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
HW = {
    "peak_bf16_flops": 197e12,      # FLOP/s
    "peak_int8_ops": 394e12,        # int8 OP/s (2x bf16 on the MXU)
    "hbm_bw": 819e9,                # B/s
    "ici_bw": 50e9,                 # B/s per link (per-direction, approx)
    "dcn_bw": 6.25e9,               # B/s per host across pods (approx 50Gbps)
}
