"""Cell builders: (arch x input-shape x mesh) -> jittable step function +
ShapeDtypeStruct inputs + shardings. Used by the multi-pod dry-run, the
roofline calculator, and the real launchers.

Shape kinds (assignment):
  train_4k    -> train_step(params, opt_state, batch)      (training)
  prefill_32k -> prefill(params, tokens[, frames])         (inference)
  decode_32k  -> serve_step(params, token, cache, index)   (one new token)
  long_500k   -> serve_step w/ 512k context, batch 1       (SSM/hybrid only)
  dit_train / dit_sample -> the paper's own model.

Sharding: params via repro.distributed rules (TP/EP on "model", FSDP on
"data" for >=2B); batch dims on the DP super-axis (("pod","data") when
multi-pod); long-context caches sequence-sharded on "data" (SP).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get as get_cfg
from repro.distributed import batch_axes, param_specs
from repro.models import (
    ModelCfg, lm_init, lm_loss_fn, lm_prefill, lm_decode_step, lm_cache_init,
    encdec_init, encdec_loss_fn, encdec_prefill, encdec_decode_step,
    encdec_cache_init, DiTCfg, dit_init, dit_apply,
)
from repro.optim import adamw, adafactor, apply_updates, cosine_schedule

FSDP_THRESHOLD = 2e9
ADAFACTOR_THRESHOLD = 3e9


# ---------------------------------------------------------------------------
# config policies per cell
# ---------------------------------------------------------------------------
def runtime_cfg(arch: str, kind: str, **extra) -> Any:
    cfg = get_cfg(arch)
    if isinstance(cfg, DiTCfg):
        over = {"scan_layers": True, "remat": kind == "dit_train"}
        over.update({k: v for k, v in extra.items()
                     if k in DiTCfg.__dataclass_fields__})
        return dataclasses.replace(cfg, **over)
    over: Dict[str, Any] = {"scan_layers": True, "remat": kind == "train"}
    if kind == "prefill":
        over["attn_impl"] = "qchunk"
        over["q_chunk"] = 2048
    over.update(extra)
    return dataclasses.replace(cfg, **over)


def n_params_of(cfg) -> int:
    return cfg.n_params()


def pick_optimizer(cfg):
    n = n_params_of(cfg)
    lr = cosine_schedule(3e-4, 2000, 100_000)
    if n > ADAFACTOR_THRESHOLD:
        return adafactor(lr), "adafactor"
    return adamw(lr, weight_decay=0.1), "adamw"


def use_fsdp(cfg) -> bool:
    return n_params_of(cfg) > FSDP_THRESHOLD


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------
def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _dp_size(mesh) -> int:
    s = _sizes(mesh)
    return int(np.prod([s[a] for a in batch_axes(mesh)]))


def batch_sharding(mesh, shape, seq_shard: bool = False):
    """Spec for an input whose dim0 is batch (guarded divisibility)."""
    spec = [None] * len(shape)
    if shape and shape[0] % _dp_size(mesh) == 0 and shape[0] > 1:
        spec[0] = batch_axes(mesh)
    return _ns(mesh, P(*spec))


def cache_sharding(mesh, shapes_tree, *, shard_batch: bool, shard_seq: bool):
    """Heuristic cache specs for stacked (L, B, S?, ...) cache leaves."""
    sizes = _sizes(mesh)
    model_n = sizes["model"]
    data_n = sizes["data"]
    dp = _dp_size(mesh)

    def per(leaf):
        shape = leaf.shape
        nd = len(shape)
        spec: list = [None] * nd
        if nd >= 2 and shard_batch and shape[1] % dp == 0 and shape[1] > 1:
            spec[1] = batch_axes(mesh)
        if nd >= 3 and shard_seq and spec[1] is None and shape[2] % data_n == 0 \
                and shape[2] >= data_n * 8:
            spec[2] = "data"
        # model axis: prefer the kv-head dim, fall back to the sequence dim
        # (sequence-sharded KV decode — GSPMD inserts the softmax-stats
        # all-reduce). NEVER shard the last (head/feature contraction) dim:
        # it conflicts with the attention dot sharding and triggers
        # involuntary full rematerialization of the cache.
        for i in range(nd - 2, 1, -1):
            if spec[i] is None and shape[i] % model_n == 0 \
                    and shape[i] >= model_n:
                spec[i] = "model"
                break
        return _ns(mesh, P(*spec))

    return jax.tree.map(per, shapes_tree)


def opt_state_shardings(opt_state_shapes, pspecs, mesh, opt_name: str):
    """Optimizer-state shardings mirroring the parameter specs."""
    rep = _ns(mesh, P())
    if opt_name == "adamw":
        ps = jax.tree.map(lambda s: _ns(mesh, s), pspecs)
        return {"step": rep, "mu": ps, "nu": ps}

    # adafactor: {'step', 'v': tree of {'vr','vc'} or {'v'}}
    def per(spec, vdict):
        if "v" in vdict:
            return {"v": _ns(mesh, spec)}
        nd = len(vdict["vr"].shape) + 1              # param ndim
        full = tuple(spec) + (None,) * (nd - len(tuple(spec)))
        return {"vr": _ns(mesh, P(*full[:-1])),
                "vc": _ns(mesh, P(*(full[:-2] + full[-1:])))}

    flat_specs, tdef = jax.tree.flatten(pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_v = tdef.flatten_up_to(opt_state_shapes["v"])
    v_shard = tdef.unflatten([per(s, v) for s, v in zip(flat_specs, flat_v)])
    return {"step": rep, "v": v_shard}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_train_step(cfg, opt, n_micro: int = 1):
    """(params, opt_state, batch) -> (loss, params, opt_state).

    n_micro > 1 splits the global batch into microbatches accumulated via
    lax.scan — bounds activation memory to one microbatch (the per-device
    HBM budget decides n_micro; see _pick_micro)."""
    if isinstance(cfg, DiTCfg):
        raise ValueError("use make_dit_train_step")
    if getattr(cfg, "encdec", False):
        loss_fn = lambda p, b: encdec_loss_fn(p, cfg, b)
    else:
        loss_fn = lambda p, b: lm_loss_fn(p, cfg, b)

    def step(params, opt_state, batch):
        if n_micro == 1:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                    + a.shape[1:]), batch)

            def body(carry, mb):
                acc, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return (jax.tree.map(jnp.add, acc, g), lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        updates, opt_state = opt.update(grads, opt_state, params)
        return loss, apply_updates(params, updates), opt_state

    return step


def _pick_micro(cfg, batch: int, seq: int, mesh) -> int:
    """Pick microbatch count so per-device activations fit ~9GB:
    carry = L * tok_loc * d * 2B (bf16 residual per layer under remat-scan)
    logits = tok_loc * (V / model) * 10B (fwd bf16 + f32 grad + lse)."""
    sizes = _sizes(mesh)
    dp = _dp_size(mesh)
    tok_loc = batch * seq // dp
    d = cfg.d_model
    L = cfg.n_layers
    v_loc = cfg.vocab / sizes["model"]
    budget = 9e9
    for n in (1, 2, 4, 8, 16, 32):
        if batch % (dp * n) and n != 1:
            continue
        carry = L * (tok_loc / n) * d * 2
        logits = (tok_loc / n) * v_loc * 10
        moe = (16 * (tok_loc / n) * d * 2) if cfg.moe else 0
        if carry + logits + moe < budget:
            return n
    return 32


def make_dit_train_step(cfg: DiTCfg, opt, sched):
    from repro.diffusion import q_sample

    def loss_fn(params, batch):
        xt = q_sample(sched, batch["x0"], batch["t"], batch["noise"])
        eps = dit_apply(params, cfg, xt, batch["t"], batch["y"])
        return jnp.mean(jnp.square(eps - batch["noise"]))

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        return loss, apply_updates(params, updates), opt_state

    return step


def make_prefill(cfg, max_len):
    if getattr(cfg, "encdec", False):
        def step(params, tokens, frames):
            return encdec_prefill(params, cfg, tokens, frames, max_len=max_len)
    else:
        def step(params, tokens):
            return lm_prefill(params, cfg, tokens, max_len=max_len)
    return step


def make_decode(cfg):
    if getattr(cfg, "encdec", False):
        def step(params, token, cache, index):
            return encdec_decode_step(params, cfg, token, cache, index)
    else:
        def step(params, token, cache, index):
            return lm_decode_step(params, cfg, token, cache, index)
    return step


def make_dit_sample_step(cfg: DiTCfg, sched_len: int = 1000):
    """One respaced ancestral denoise step (the serving unit of a DiT)."""
    from repro.diffusion import DiffusionCfg, make_schedule
    sched = make_schedule(DiffusionCfg(T=sched_len))

    def step(params, x, t, y, noise):
        eps = dit_apply(params, cfg, x, t, y)
        abar = sched["abar"][t].reshape(-1, 1, 1, 1)
        alpha = sched["alphas"][t].reshape(-1, 1, 1, 1)
        beta = sched["betas"][t].reshape(-1, 1, 1, 1)
        abar_prev = sched["abar_prev"][t].reshape(-1, 1, 1, 1)
        x0 = (x - jnp.sqrt(1 - abar) * eps) / jnp.sqrt(abar)
        mean = (jnp.sqrt(abar_prev) * beta / (1 - abar) * x0
                + jnp.sqrt(alpha) * (1 - abar_prev) / (1 - abar) * x)
        return mean + jnp.sqrt(sched["post_var"][t].reshape(-1, 1, 1, 1)) * noise

    return step


# ---------------------------------------------------------------------------
# cell builder
# ---------------------------------------------------------------------------
def build_cell(arch: str, shape_id: str, mesh: Mesh,
               cfg_overrides: Optional[Dict[str, Any]] = None,
               force_micro: Optional[int] = None,
               replicate_params: bool = False) -> Dict[str, Any]:
    """Returns {'fn', 'args' (ShapeDtypeStructs), 'in_shardings',
    'donate_argnums', 'meta'} ready for jax.jit().lower(*args).
    replicate_params=True serves with pure DP (no TP collectives)."""
    from repro.configs import SHAPES, DIT_SHAPES
    meta = (DIT_SHAPES if arch == "dit-xl-2" else SHAPES)[shape_id]
    kind = meta["kind"]
    cfg = runtime_cfg(arch, kind, **(cfg_overrides or {}))
    key = jax.random.PRNGKey(0)

    if isinstance(cfg, DiTCfg):
        params = jax.eval_shape(lambda k: dit_init(k, cfg), key)
    elif getattr(cfg, "encdec", False):
        params = jax.eval_shape(lambda k: encdec_init(k, cfg), key)
    else:
        params = jax.eval_shape(lambda k: lm_init(k, cfg), key)
    # FSDP only where the params need it: always for training (optimizer
    # state), but at inference dense archs fit TP-sharded (chameleon-34b:
    # 4.3 GB/device) and ZeRO's per-layer weight all-gather is pure decode
    # overhead (measured 213 ms/step collective; EXPERIMENTS §Perf). MoE
    # archs keep FSDP at inference: expert tables exceed HBM at EP=16.
    if isinstance(cfg, DiTCfg):
        fsdp = False
    else:
        fsdp = use_fsdp(cfg) and (kind == "train" or cfg.moe)
    pspecs = param_specs(params, mesh, fsdp=fsdp)
    if replicate_params:
        pspecs = jax.tree.map(lambda s: P(), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
    pshard = jax.tree.map(lambda s: _ns(mesh, s), pspecs)

    info = {"arch": arch, "shape": shape_id, "kind": kind, "fsdp": fsdp,
            "n_params": n_params_of(cfg)}

    if kind in ("train",):
        opt, opt_name = pick_optimizer(cfg)
        opt_state = jax.eval_shape(opt.init, params)
        oshard = opt_state_shardings(opt_state, pspecs, mesh, opt_name)
        B, S = meta["batch"], meta["seq"]
        n_micro = force_micro or _pick_micro(cfg, B, S, mesh)
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "labels": _sds((B, S), jnp.int32)}
        bshard = {k: batch_sharding(mesh, v.shape) for k, v in batch.items()}
        if getattr(cfg, "encdec", False):
            batch["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), cfg.jdtype)
            bshard["frames"] = batch_sharding(mesh, batch["frames"].shape)
        fn = make_train_step(cfg, opt, n_micro=n_micro)
        info["optimizer"] = opt_name
        info["n_micro"] = n_micro
        return {"fn": fn, "args": (params, opt_state, batch),
                "in_shardings": (pshard, oshard, bshard),
                "donate_argnums": (0, 1), "meta": info}

    if kind == "prefill":
        B, S = meta["batch"], meta["seq"]
        fn = make_prefill(cfg, max_len=S)
        tokens = _sds((B, S), jnp.int32)
        args = [params, tokens]
        shards = [pshard, batch_sharding(mesh, (B, S))]
        if getattr(cfg, "encdec", False):
            frames = _sds((B, cfg.enc_seq, cfg.d_model), cfg.jdtype)
            args.append(frames)
            shards.append(batch_sharding(mesh, frames.shape))
        return {"fn": fn, "args": tuple(args), "in_shardings": tuple(shards),
                "donate_argnums": (), "meta": info}

    if kind == "decode":
        B, S = meta["batch"], meta["seq"]
        fn = make_decode(cfg)
        if getattr(cfg, "encdec", False):
            cache = jax.eval_shape(
                lambda: encdec_cache_init(cfg, B, S))
        else:
            cache = jax.eval_shape(lambda: lm_cache_init(cfg, B, S))
        cshard = cache_sharding(mesh, cache, shard_batch=B > 1,
                                shard_seq=B == 1)
        token = _sds((B, 1), jnp.int32)
        index = _sds((), jnp.int32)
        return {"fn": fn,
                "args": (params, token, cache, index),
                "in_shardings": (pshard, batch_sharding(mesh, (B, 1)),
                                 cshard, _ns(mesh, P())),
                "donate_argnums": (2,), "meta": info}

    if kind == "dit_train":
        from repro.diffusion import DiffusionCfg, make_schedule
        opt, opt_name = pick_optimizer_dit(cfg)
        opt_state = jax.eval_shape(opt.init, params)
        oshard = opt_state_shardings(opt_state, pspecs, mesh, opt_name)
        sched = make_schedule(DiffusionCfg(T=1000))
        B = meta["batch"]
        batch = {
            "x0": _sds((B, cfg.img_size, cfg.img_size, cfg.in_ch), jnp.float32),
            "t": _sds((B,), jnp.int32),
            "y": _sds((B,), jnp.int32),
            "noise": _sds((B, cfg.img_size, cfg.img_size, cfg.in_ch),
                          jnp.float32),
        }
        bshard = {k: batch_sharding(mesh, v.shape) for k, v in batch.items()}
        fn = make_dit_train_step(cfg, opt, sched)
        info["optimizer"] = opt_name
        return {"fn": fn, "args": (params, opt_state, batch),
                "in_shardings": (pshard, oshard, bshard),
                "donate_argnums": (0, 1), "meta": info}

    if kind == "dit_sample":
        B = meta["batch"]
        fn = make_dit_sample_step(cfg)
        x = _sds((B, cfg.img_size, cfg.img_size, cfg.in_ch), jnp.float32)
        t = _sds((B,), jnp.int32)
        y = _sds((B,), jnp.int32)
        noise = _sds((B, cfg.img_size, cfg.img_size, cfg.in_ch), jnp.float32)
        bs = batch_sharding(mesh, x.shape)
        return {"fn": fn, "args": (params, x, t, y, noise),
                "in_shardings": (pshard, bs, batch_sharding(mesh, (B,)),
                                 batch_sharding(mesh, (B,)), bs),
                "donate_argnums": (1,), "meta": info}

    raise ValueError(kind)


def pick_optimizer_dit(cfg: DiTCfg):
    lr = cosine_schedule(1e-4, 1000, 400_000)
    return adamw(lr, weight_decay=0.0), "adamw"
