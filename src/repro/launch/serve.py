"""Serving launcher: batched decode for LMs / batched DDPM sampling for
DiT, with optional W8A8 quantized execution (the paper's deployment
path: calibrate once with TQ-DiT, then serve quantized).

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt_len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-2 --smoke \
      --batch 4 --steps 25 --quantize w8a8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--steps", type=int, default=25, help="DiT sample steps")
    ap.add_argument("--quantize", default=None, choices=(None, "w8a8", "w6a6"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get, get_smoke
    from repro.models import (DiTCfg, lm_init, lm_generate, dit_init)
    from repro.nn.ctx import FPContext

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    ctx = FPContext()

    if isinstance(cfg, DiTCfg):
        from repro.diffusion import DiffusionCfg, make_schedule, ddpm_sample
        from repro.models import dit_apply
        params = dit_init(key, cfg)
        dif = DiffusionCfg(T=1000)
        sched = make_schedule(dif)
        if args.quantize:
            from repro.core import (PTQConfig, run_ptq, make_quant_context,
                                    build_dit_calibration, dit_loss_fn)
            from repro.core.baselines import tq_dit
            bits = 8 if args.quantize == "w8a8" else 6
            lp_key, key = jax.random.split(key)
            x0_src = lambda n, k: jax.random.normal(
                k, (n, cfg.img_size, cfg.img_size, cfg.in_ch))
            calib = build_dit_calibration(
                params, cfg, dif, sched, x0_src, lp_key, n_per_group=4,
                batch=4)
            qp, rep = run_ptq(dit_loss_fn(params, cfg), calib,
                              tq_dit(bits, bits, n_alpha=8, rounds=2))
            ctx = make_quant_context(qp)
            print(f"calibrated {rep['n_quantized']} ops in "
                  f"{rep['wall_s']:.1f}s ({args.quantize})")
        eps_fn = lambda x, t, y, c: dit_apply(params, cfg, x, t, y, ctx=c)
        t0 = time.perf_counter()
        out = ddpm_sample(eps_fn, dif, sched,
                          (args.batch, cfg.img_size, cfg.img_size, cfg.in_ch),
                          jnp.zeros((args.batch,), jnp.int32), key,
                          steps=args.steps, ctx=ctx)
        out.block_until_ready()
        dt = time.perf_counter() - t0
        print(f"sampled {args.batch} latents x {args.steps} steps in "
              f"{dt:.2f}s ({dt/args.steps*1000:.0f} ms/step); "
              f"mean={float(out.mean()):.4f} std={float(out.std()):.4f}")
        return

    params = lm_init(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    t0 = time.perf_counter()
    toks = lm_generate(params, cfg, prompts, args.gen, ctx=ctx,
                       max_len=args.prompt_len + args.gen)
    toks.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({dt/args.gen*1000:.0f} ms/token batched)")
    print("sample:", np.asarray(toks[0])[:16])


if __name__ == "__main__":
    main()
