"""Serving launcher — a thin CLI over ``repro.serving``.

DiT archs run through the sharded batched serving subsystem: a request
stream is coalesced into fixed-shape microbatches (step-bucketed, padded,
CFG-paired) and executed data-parallel via shard_map; ``--quantize w8a8``
serves through the fused int8 Pallas kernels. LM archs keep the simple
batched-decode path.

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-2 --smoke \
      --requests 8 --microbatch 4 --steps 4 --quantize w8a8
  PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-2 --smoke \
      --requests 8 --dp 2 --cfg-scale 1.5
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt_len 32 --gen 16

``--dp N`` forces N host devices (XLA_FLAGS) for data-parallel serving on
CPU; it must be set before jax initializes, which is why all jax imports
live inside ``main``.
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4, help="LM decode batch")
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8,
                    help="DiT: number of generation requests to serve")
    ap.add_argument("--microbatch", type=int, default=4,
                    help="DiT: slots per compiled microbatch")
    ap.add_argument("--steps", type=int, default=25, help="DiT sample steps")
    ap.add_argument("--cfg-scale", type=float, default=1.0,
                    help="classifier-free guidance scale (1 = conditional)")
    ap.add_argument("--dp", type=int, default=0,
                    help="force N host devices for data-parallel serving "
                         "(0 = use whatever the backend exposes)")
    # NOTE: argparse compares the supplied value against `choices` AFTER
    # applying `type`; a None inside choices only matches when the flag is
    # omitted entirely, and `--quantize` with no sane sentinel rejected the
    # default-unset path on some invocations. "none" is the sentinel.
    ap.add_argument("--quantize", default="none",
                    choices=("none", "w8a8", "w6a6"))
    ap.add_argument("--calib", default="range", choices=("range", "ho"),
                    help="w8a8/w6a6 calibration: fast range-only (serving "
                         "bring-up) or the paper's full HO search")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.dp > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.dp}")

    import jax
    import numpy as np

    from repro.configs import get, get_smoke
    from repro.models import DiTCfg, lm_init, lm_generate
    from repro.nn.ctx import FPContext

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    ctx = FPContext()

    if isinstance(cfg, DiTCfg):
        from repro.diffusion import DiffusionCfg, make_schedule
        from repro.launch.mesh import make_serving_mesh
        from repro.models import dit_init
        from repro.serving import RequestScheduler, ServeEngine

        params = dit_init(key, cfg)
        dif = DiffusionCfg(T=1000)
        sched = make_schedule(dif)

        if args.quantize != "none":
            bits = 8 if args.quantize == "w8a8" else 6
            lp_key, key = jax.random.split(key)
            if args.calib == "range":
                from repro.serving import range_calibrate
                t0 = time.perf_counter()
                qp, weights = range_calibrate(params, cfg, dif, sched,
                                              lp_key, wbits=bits, abits=bits)
                print(f"range-calibrated {len(qp)} linears in "
                      f"{time.perf_counter() - t0:.1f}s ({args.quantize})")
            else:
                from repro.core import (build_dit_calibration, dit_loss_fn,
                                        run_ptq)
                from repro.core.baselines import tq_dit
                x0_src = lambda n, k: jax.random.normal(
                    k, (n, cfg.img_size, cfg.img_size, cfg.in_ch))
                calib = build_dit_calibration(
                    params, cfg, dif, sched, x0_src, lp_key, n_per_group=4,
                    batch=4)
                qp, rep = run_ptq(dit_loss_fn(params, cfg), calib,
                                  tq_dit(bits, bits, n_alpha=8, rounds=2))
                weights = rep["weights"]
                print(f"HO-calibrated {rep['n_quantized']} ops in "
                      f"{rep['wall_s']:.1f}s ({args.quantize})")
            from repro.core import make_quant_context
            if bits == 8:
                # deployment path: pack + fused int8 Pallas kernels
                from repro.kernels import ops as kops
                qp = kops.convert_for_kernels(qp, weights)
                n_pack = sum(1 for v in qp.values()
                             if "int8" in v or "int8_mrq" in v)
                print(f"packed {n_pack} linears for the fused int8 kernels")
                ctx = make_quant_context(qp, kernel=True)
            else:
                ctx = make_quant_context(qp)          # fake-quant (no 6-bit MXU)

        mesh = make_serving_mesh()
        engine = ServeEngine(params, cfg, dif, sched, ctx=ctx, mesh=mesh,
                             microbatch=args.microbatch,
                             step_buckets=(args.steps,))
        sched_q = RequestScheduler(microbatch=args.microbatch,
                                   step_buckets=(args.steps,))
        rkey = jax.random.PRNGKey(args.seed + 1)
        labels = jax.random.randint(rkey, (args.requests,), 0, cfg.n_classes)
        for i in range(args.requests):
            sched_q.submit(int(labels[i]), steps=args.steps,
                           cfg_scale=args.cfg_scale,
                           seed=args.seed * 100_000 + i)
        t0 = time.perf_counter()
        results = sched_q.run(engine)
        dt = time.perf_counter() - t0
        samples = np.stack([results[r].sample for r in sorted(results)])
        st = engine.stats
        print(f"served {len(results)} requests x {args.steps} steps on "
              f"{jax.device_count()} device(s) in {dt:.2f}s "
              f"({len(results) / dt:.2f} req/s, "
              f"{dt / (st['microbatches'] * args.steps) * 1000:.0f} ms/step); "
              f"{st['microbatches']} microbatches, "
              f"{st['padded_slots']} padded slots, "
              f"buckets compiled: {st['compiled_buckets']}")
        print(f"sample mean={samples.mean():.4f} std={samples.std():.4f}")
        return

    params = lm_init(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    t0 = time.perf_counter()
    toks = lm_generate(params, cfg, prompts, args.gen, ctx=ctx,
                       max_len=args.prompt_len + args.gen)
    toks.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({dt/args.gen*1000:.0f} ms/token batched)")
    print("sample:", np.asarray(toks[0])[:16])


if __name__ == "__main__":
    main()
