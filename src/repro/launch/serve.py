"""Serving launcher — a thin CLI over ``repro.serving``.

DiT archs run through the sharded batched serving subsystem: a request
stream is coalesced into fixed-shape microbatches (step-bucketed, padded,
CFG-paired) and executed data-parallel via shard_map; ``--quantize``
serves through the Pallas kernel family for the chosen bits (w8a8/w6a6:
fused int8 kernels; w4a4: nibble-packed int4 kernels). LM archs keep the
simple batched-decode path.

Quantized serving goes through the unified API (``repro.quant``):
``--quantize w8a8`` builds a ``QuantRecipe``, runs ``quantize()`` and
serves the returned ``QuantArtifact``; ``--save-artifact DIR`` persists
it, and ``--load-artifact DIR`` cold-starts a later process from disk —
the expensive calibration never reruns, and the served samples are
bit-identical to the calibrating process (asserted in
``tests/test_quant_api.py``).

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-2 --smoke \
      --requests 8 --microbatch 4 --steps 4 --quantize w8a8
  PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-2 --smoke \
      --requests 8 --microbatch 4 --steps 4 --quantize w8a8 \
      --save-artifact /tmp/dit_w8a8
  PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-2 --smoke \
      --requests 8 --microbatch 4 --steps 4 --quantize w8a8 \
      --load-artifact /tmp/dit_w8a8
  PYTHONPATH=src python -m repro.launch.serve --arch dit-xl-2 --smoke \
      --requests 8 --dp 2 --cfg-scale 1.5
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
      --batch 4 --prompt_len 32 --gen 16

``--dp N`` forces N host devices (XLA_FLAGS) for data-parallel serving on
CPU; it must be set before jax initializes, which is why all jax imports
live inside ``main``.
"""
from __future__ import annotations

import argparse
import os
import time
import warnings


def fake_quant_fallback_warning(artifact) -> "str | None":
    """The message served when a quantized artifact cannot lower (fully)
    onto the Pallas kernels, or None when every quantized matmul runs a
    kernel. Two shapes of failure, both said out loud:

    - no packs at all (an artifact from an older writer): the whole
      serve is fake-quant;
    - PARTIAL packs: ``artifact.fallback_ops()`` is non-empty — the
      message names exactly which op ids fell back and how many, so a
      deploy log never hides a per-op fp island. Since prescale folding
      landed, ``channel_balance=True`` recipes pack everything and this
      returns None.

    A named helper so the no-silent-fallback contract is testable
    without spinning up an engine: every --quantize/--load-artifact
    serve either runs the packed kernels or says which ops do not.
    """
    if not artifact.has_kernel_packs:
        return (
            f"artifact {artifact.recipe.bits}/{artifact.recipe.method} "
            "carries no kernel packs: serving falls back to the FAKE-QUANT "
            "path (simulated quant-dequant in fp32 — no int8/int4 Pallas "
            "kernels, no weight-traffic win). Re-quantize with a "
            "kernel-deployable recipe (w8a8/w6a6 -> fused int8 kernels, "
            "w4a4 -> packed int4) for the deployment path.")
    fb = artifact.fallback_ops()
    if not fb:
        return None
    shown = ", ".join(fb[:8]) + (", ..." if len(fb) > 8 else "")
    return (
        f"artifact {artifact.recipe.bits}/{artifact.recipe.method}: "
        f"{len(fb)} quantized op(s) carry no kernel pack and fall back to "
        f"the FAKE-QUANT path: {shown}. Every other op runs the Pallas "
        "kernels; re-quantize to clear the residue.")


def _warn_if_fake_quant(artifact) -> None:
    msg = fake_quant_fallback_warning(artifact)
    if msg is not None:
        warnings.warn(msg, RuntimeWarning, stacklevel=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4, help="LM decode batch")
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8,
                    help="DiT: number of generation requests to serve")
    ap.add_argument("--microbatch", type=int, default=4,
                    help="DiT: slots per compiled microbatch")
    ap.add_argument("--steps", type=int, default=25, help="DiT sample steps")
    ap.add_argument("--cfg-scale", type=float, default=1.0,
                    help="classifier-free guidance scale (1 = conditional)")
    ap.add_argument("--dp", type=int, default=0,
                    help="force N host devices for data-parallel serving "
                         "(0 = use whatever the backend exposes)")
    # NOTE: argparse compares the supplied value against `choices` AFTER
    # applying `type`; a None inside choices only matches when the flag is
    # omitted entirely, and `--quantize` with no sane sentinel rejected the
    # default-unset path on some invocations. "none" is the sentinel.
    ap.add_argument("--quantize", default="none",
                    choices=("none", "w8a8", "w6a6", "w4a4"))
    ap.add_argument("--calib", default="range", choices=("range", "ho"),
                    help="calibration: fast range-only (serving "
                         "bring-up) or the paper's full HO search")
    ap.add_argument("--attn-impl", default=None,
                    choices=("flash", "composed"),
                    help="attention lowering: 'flash' = one fused "
                         "Pallas kernel (default; no (S,S) HBM "
                         "round-trip), 'composed' = the three-kernel "
                         "exactness oracle. Unset keeps the recipe/"
                         "artifact default")
    ap.add_argument("--save-artifact", default=None, metavar="DIR",
                    help="after calibrating, persist the QuantArtifact "
                         "(qparams + int8 packs + recipe + provenance) so "
                         "later processes cold-start with --load-artifact")
    ap.add_argument("--load-artifact", default=None, metavar="DIR",
                    help="serve from a saved QuantArtifact — NO calibration "
                         "runs in this process; with --quantize the "
                         "artifact's recorded bits must match")
    ap.add_argument("--dump-samples", default=None, metavar="NPY",
                    help="np.save the served samples (request-id order) — "
                         "used by tests to assert bit-identity across "
                         "artifact save/load")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="DiT: serve through the fault-tolerant async "
                         "continuous-batching engine (slot pool, chunked "
                         "dispatches, NaN quarantine, deadlines) instead "
                         "of the synchronous step-bucketed path; samples "
                         "are bit-identical either way. Composes with "
                         "--dp N: the slot pool shards across the "
                         "data-parallel mesh (microbatch must divide by N)")
    ap.add_argument("--chunk", type=int, default=4,
                    help="async: denoising steps advanced per compiled "
                         "dispatch (the admission/cancellation granularity)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="async: per-request deadline; requests not "
                         "finished by a chunk boundary past it are "
                         "CANCELLED (structured outcome, slot freed)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="async: NaN-quarantine retries per request before "
                         "a structured FAILED outcome")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.save_artifact is not None and (args.quantize == "none"
                                           or args.load_artifact is not None):
        ap.error("--save-artifact requires --quantize (and excludes "
                 "--load-artifact): there is no freshly calibrated "
                 "artifact to save otherwise")
    if args.dp > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.dp}")

    import jax
    import numpy as np

    from repro.configs import get, get_smoke
    from repro.models import DiTCfg, lm_init, lm_generate
    from repro.nn.ctx import FPContext

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    ctx = FPContext()

    if isinstance(cfg, DiTCfg):
        from repro.diffusion import DiffusionCfg, make_schedule
        from repro.launch.mesh import make_serving_mesh
        from repro.models import dit_init
        from repro.serving import AsyncServeEngine, RequestScheduler, \
            ServeEngine

        params = dit_init(key, cfg)
        dif = DiffusionCfg(T=1000)
        sched = make_schedule(dif)
        mesh = make_serving_mesh()
        artifact = None
        deadline_s = (args.deadline_ms / 1000.0
                      if args.deadline_ms is not None else None)
        # async + dp: the slot pool shards across the same DP mesh as the
        # sync path (one slot-pool slice per device, shard_map'd chunks)
        async_kw = dict(mesh=mesh if args.dp > 1 else None,
                        microbatch=args.microbatch,
                        step_buckets=(args.steps,), chunk=args.chunk,
                        max_retries=args.max_retries, deadline_s=deadline_s)

        if args.load_artifact is not None:
            # cold-start: the saved artifact IS the calibration — nothing
            # is recalibrated in this process.
            from repro.quant import QuantArtifact
            t0 = time.perf_counter()
            artifact = QuantArtifact.load(args.load_artifact)
            if args.quantize != "none" \
                    and artifact.recipe.bits != args.quantize:
                raise SystemExit(
                    f"--quantize {args.quantize} but artifact at "
                    f"{args.load_artifact} was calibrated at "
                    f"{artifact.recipe.bits} ({artifact.summary()})")
            print(f"loaded {artifact.summary()} in "
                  f"{time.perf_counter() - t0:.1f}s — no calibration run")
            _warn_if_fake_quant(artifact)
            # no sched= here: the artifact's recorded DiffusionCfg is the
            # source of truth (the CLI-built schedule would silently win
            # over an artifact calibrated under a different chain)
            if args.async_mode:
                engine = AsyncServeEngine.from_artifact(
                    params, artifact, attn_impl=args.attn_impl, **async_kw)
            else:
                engine = ServeEngine.from_artifact(
                    params, artifact, mesh=mesh, attn_impl=args.attn_impl,
                    microbatch=args.microbatch, step_buckets=(args.steps,))
        else:
            if args.quantize != "none":
                from repro.quant import QuantRecipe, quantize
                # HO-only knobs stay at defaults for --calib range: the
                # recipe must describe what ran (quantize() enforces it)
                ho_kw = {"n_alpha": 8, "rounds": 2} \
                    if args.calib == "ho" else {}
                if args.attn_impl is not None:
                    ho_kw["attn_impl"] = args.attn_impl
                recipe = QuantRecipe(bits=args.quantize, method=args.calib,
                                     seed=args.seed, **ho_kw)
                t0 = time.perf_counter()
                artifact = quantize(params, cfg, dif, recipe, sched=sched,
                                    provenance={"arch": args.arch,
                                                "smoke": args.smoke})
                print(f"{args.calib}-calibrated {artifact.summary()} in "
                      f"{time.perf_counter() - t0:.1f}s")
                _warn_if_fake_quant(artifact)
                ctx = artifact.context()      # packed kernels iff packs exist
                if args.save_artifact is not None:
                    artifact.save(args.save_artifact)
                    print(f"saved artifact -> {args.save_artifact}")
            if args.async_mode:
                engine = AsyncServeEngine(params, cfg, dif, sched, ctx=ctx,
                                          **async_kw)
            else:
                engine = ServeEngine(params, cfg, dif, sched, ctx=ctx,
                                     mesh=mesh, microbatch=args.microbatch,
                                     step_buckets=(args.steps,))
        rkey = jax.random.PRNGKey(args.seed + 1)
        labels = jax.random.randint(rkey, (args.requests,), 0, cfg.n_classes)

        if args.async_mode:
            t0 = time.perf_counter()
            for i in range(args.requests):
                engine.submit(int(labels[i]), steps=args.steps,
                              cfg_scale=args.cfg_scale,
                              seed=args.seed * 100_000 + i)
            outcomes = engine.run_until_drained()
            dt = time.perf_counter() - t0
            ok = {r: o for r, o in outcomes.items() if o.status == "OK"}
            samples = np.stack([ok[r].sample for r in sorted(ok)])
            if args.dump_samples is not None:
                np.save(args.dump_samples, samples)
                print(f"dumped {samples.shape} samples -> "
                      f"{args.dump_samples}")
            st, m = engine.stats, engine.metrics()
            print(f"async-served {len(outcomes)} requests x {args.steps} "
                  f"steps (chunk={args.chunk}) in {dt:.2f}s: "
                  f"{m['by_status']}, goodput {m['goodput_rps']:.2f} ok/s, "
                  f"latency p50/p99 {m['latency_p50_s']:.2f}/"
                  f"{m['latency_p99_s']:.2f}s, queue-wait p50 "
                  f"{m['queue_wait_p50_s']:.2f}s")
            print(f"{st['dispatches']} dispatches, {st['chunk_traces']} "
                  f"chunk trace(s), {st['retries']} retries, "
                  f"{len(st['degradations'])} degradations")
            print(f"sample mean={samples.mean():.4f} "
                  f"std={samples.std():.4f}")
            return

        sched_q = RequestScheduler(microbatch=args.microbatch,
                                   step_buckets=(args.steps,),
                                   n_classes=cfg.n_classes)
        for i in range(args.requests):
            sched_q.submit(int(labels[i]), steps=args.steps,
                           cfg_scale=args.cfg_scale,
                           seed=args.seed * 100_000 + i)
        t0 = time.perf_counter()
        results = sched_q.run(engine)
        dt = time.perf_counter() - t0
        samples = np.stack([results[r].sample for r in sorted(results)])
        if args.dump_samples is not None:
            np.save(args.dump_samples, samples)
            print(f"dumped {samples.shape} samples -> {args.dump_samples}")
        st = engine.stats
        print(f"served {len(results)} requests x {args.steps} steps on "
              f"{jax.device_count()} device(s) in {dt:.2f}s "
              f"({len(results) / dt:.2f} req/s, "
              f"{dt / (st['microbatches'] * args.steps) * 1000:.0f} ms/step); "
              f"{st['microbatches']} microbatches, "
              f"{st['padded_slots']} padded slots, "
              f"buckets compiled: {st['compiled_buckets']}")
        print(f"sample mean={samples.mean():.4f} std={samples.std():.4f}")
        return

    if args.save_artifact or args.load_artifact or args.dump_samples:
        raise SystemExit(
            f"--save-artifact/--load-artifact/--dump-samples are DiT-only "
            f"({args.arch} takes the LM decode path, which has no artifact "
            "support); drive LM PTQ via repro.core.run_ptq for now")
    params = lm_init(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    t0 = time.perf_counter()
    toks = lm_generate(params, cfg, prompts, args.gen, ctx=ctx,
                       max_len=args.prompt_len + args.gen)
    toks.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({dt/args.gen*1000:.0f} ms/token batched)")
    print("sample:", np.asarray(toks[0])[:16])


if __name__ == "__main__":
    main()
