"""HLO text parsing: collective bytes + op counts from lowered/compiled
modules. cost_analysis() does not expose collective traffic, so the
roofline's collective term comes from summing the output-shape bytes of
every collective op in the HLO text.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %all-gather.3 = bf16[16,2048,128]{2,1,0} all-gather(...)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, dict]:
    """Returns {op_kind: {'count': int, 'bytes': int}} over the module.
    Bytes are OUTPUT bytes of each collective op instance (per device)."""
    out: Dict[str, dict] = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(shape_str)
    return out


def total_collective_bytes(hlo_text: str) -> int:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


def count_ops(hlo_text: str, name: str) -> int:
    return len(re.findall(rf"\b{re.escape(name)}\b", hlo_text))
