"""Training launcher (single-host entry point; multi-pod via
jax.distributed initialization when COORDINATOR_ADDRESS is set).

Fault tolerance: atomic async checkpoints every --ckpt_every steps with
automatic resume-from-latest; data pipeline is step-indexed so a restart
replays no batch twice; straggler mitigation at this layer is timeout-
based step watchdogs (log-only on CPU).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --steps 50 --batch 8 --seq 256 --smoke           # CPU-scale smoke
  PYTHONPATH=src python -m repro.launch.train --arch dit-xl-2 --smoke ...
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-scale)")
    ap.add_argument("--ckpt_dir", default=None)
    ap.add_argument("--ckpt_every", type=int, default=20)
    ap.add_argument("--grad_accum", type=int, default=1)
    ap.add_argument("--data_mesh", type=int, default=1)
    ap.add_argument("--model_mesh", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log_every", type=int, default=10)
    args = ap.parse_args()

    if os.environ.get("COORDINATOR_ADDRESS"):
        jax.distributed.initialize()

    from repro.configs import get, get_smoke
    from repro.data import TokenPipeline, LatentPipeline
    from repro.distributed import param_specs
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.steps import make_train_step, make_dit_train_step
    from repro.models import DiTCfg, lm_init, encdec_init, dit_init
    from repro.optim import adamw, cosine_schedule
    from repro import checkpoint as ckpt
    from jax.sharding import NamedSharding

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    mesh = make_debug_mesh(args.data_mesh, args.model_mesh)
    key = jax.random.PRNGKey(args.seed)
    opt = adamw(cosine_schedule(args.lr, max(args.steps // 20, 5), args.steps),
                weight_decay=0.01)

    is_dit = isinstance(cfg, DiTCfg)
    if is_dit:
        params = dit_init(key, cfg)
        from repro.diffusion import DiffusionCfg, make_schedule
        sched = make_schedule(DiffusionCfg(T=1000))
        step_fn = make_dit_train_step(cfg, opt, sched)
        pipe = LatentPipeline(cfg.img_size, cfg.in_ch, cfg.n_classes,
                              seed=args.seed)
    elif getattr(cfg, "encdec", False):
        params = encdec_init(key, cfg)
        step_fn = make_train_step(cfg, opt, n_micro=args.grad_accum)
        pipe = TokenPipeline(cfg.vocab, args.seq, args.batch, seed=args.seed)
    else:
        params = lm_init(key, cfg)
        step_fn = make_train_step(cfg, opt, n_micro=args.grad_accum)
        pipe = TokenPipeline(cfg.vocab, args.seq, args.batch, seed=args.seed)

    opt_state = opt.init(params)
    start = 0
    if args.ckpt_dir:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(args.ckpt_dir,
                                 {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = latest
            print(f"resumed from step {start}")

    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          param_specs(params, mesh))
    with mesh:
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        t0 = time.perf_counter()
        for step in range(start, args.steps):
            if is_dit:
                key, k1, k2, k3 = jax.random.split(key, 4)
                x0, y = pipe.sample(args.batch, k1)
                batch = {"x0": x0, "y": y,
                         "t": jax.random.randint(k2, (args.batch,), 0, 1000),
                         "noise": jax.random.normal(k3, x0.shape)}
            else:
                batch = pipe.batch_at(step)
                if getattr(cfg, "encdec", False):
                    key, k1 = jax.random.split(key)
                    batch["frames"] = jax.random.normal(
                        k1, (args.batch, cfg.enc_seq, cfg.d_model),
                        cfg.jdtype)
            loss, params, opt_state = jstep(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = (time.perf_counter() - t0) / max(step - start + 1, 1)
                print(f"step {step:5d} loss {float(loss):.4f} "
                      f"({dt*1000:.0f} ms/step)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(args.ckpt_dir, step + 1,
                                {"params": params, "opt": opt_state})
        if args.ckpt_dir:
            ckpt.wait_async()
            ckpt.save(args.ckpt_dir, args.steps,
                      {"params": params, "opt": opt_state})
    print("done.")


if __name__ == "__main__":
    main()
