"""dit-xl-2 — the paper's own model (Peebles & Xie 2023): DiT-XL/2 on
256x256 ImageNet latents (32x32x4 SD-VAE, patch 2 -> 256 tokens).
28L d_model=1152 16H mlp_ratio=4 n_classes=1000.
"""
from repro.models.dit import DiTCfg


def full() -> DiTCfg:
    return DiTCfg(
        img_size=32, in_ch=4, patch=2, d_model=1152, n_layers=28,
        n_heads=16, mlp_ratio=4.0, n_classes=1000, dtype="bfloat16",
    )


def smoke() -> DiTCfg:
    return DiTCfg(
        img_size=8, in_ch=4, patch=2, d_model=64, n_layers=2,
        n_heads=4, mlp_ratio=4.0, n_classes=8,
    )
