"""hymba-1.5b [hybrid] — parallel attention + Mamba heads per block,
sliding-window attention with 3 global layers and 128 meta tokens.
32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001 ssm_state=16.
[arXiv:2411.13676; hf]
"""
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, vocab=32001,
        attn_type="gqa", n_heads=25, n_kv_heads=5, head_dim=64,
        window=1024, global_layers=(0, 15, 31), n_meta=128,
        block_type="hymba", d_ff=5504, mlp_act="swiglu",
        ssm=True, d_inner=3200, ssm_state=16, ssm_head_dim=64,
        ssm_chunk=256, ssm_groups=1,
        norm="rmsnorm", tie_embeddings=True, pos_embed="rope",
        max_seq=1 << 20, dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="hymba-smoke", family="hybrid",
        n_layers=3, d_model=64, vocab=256,
        attn_type="gqa", n_heads=4, n_kv_heads=2, head_dim=16,
        window=8, global_layers=(0, 2), n_meta=4,
        block_type="hymba", d_ff=128, mlp_act="swiglu",
        ssm=True, d_inner=128, ssm_state=8, ssm_head_dim=32,
        ssm_chunk=8, ssm_groups=1,
        norm="rmsnorm", tie_embeddings=True, max_seq=4096,
    )
