"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed
experts top-6. 60L d_model=5120 128H d_expert=1536 vocab=102400.
[arXiv:2405.04434; hf]

Deviation noted in DESIGN: the real model's first layer is a dense MLP;
we keep all layers MoE so the stacked-layer scan stays uniform.
"""
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, vocab=102400,
        attn_type="mla", n_heads=128,
        kv_lora=512, q_lora=1536, nope_dim=128, rope_dim=64, v_dim=128,
        moe=True, n_experts=160, top_k=6, n_shared=2, d_expert=1536,
        d_ff=0, mlp_act="swiglu", capacity_factor=1.25,
        norm="rmsnorm", tie_embeddings=False, pos_embed="rope",
        max_seq=32768, dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="deepseek-v2-smoke", family="moe",
        n_layers=2, d_model=64, vocab=256,
        attn_type="mla", n_heads=4,
        kv_lora=32, q_lora=32, nope_dim=16, rope_dim=8, v_dim=16,
        moe=True, n_experts=8, top_k=2, n_shared=1, d_expert=32,
        d_ff=0, mlp_act="swiglu",
        norm="rmsnorm", tie_embeddings=False, max_seq=1024,
    )
