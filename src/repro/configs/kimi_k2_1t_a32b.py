"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE: 384 routed experts
top-8 + 1 shared. 61L d_model=7168 64H (GQA kv=8 per assignment)
d_expert=2048 vocab=163840. [arXiv:2501.kimi2; unverified, paper-table]
"""
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, vocab=163840,
        attn_type="gqa", n_heads=64, n_kv_heads=8, head_dim=128,
        qkv_bias=False, rope_theta=5e6,
        moe=True, n_experts=384, top_k=8, n_shared=1, d_expert=2048,
        d_ff=0, mlp_act="swiglu", capacity_factor=1.25,
        norm="rmsnorm", tie_embeddings=False, pos_embed="rope",
        max_seq=131072, dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="kimi-k2-smoke", family="moe",
        n_layers=2, d_model=64, vocab=256,
        attn_type="gqa", n_heads=4, n_kv_heads=2, head_dim=16,
        moe=True, n_experts=8, top_k=2, n_shared=1, d_expert=32,
        d_ff=0, mlp_act="swiglu",
        norm="rmsnorm", tie_embeddings=False, max_seq=1024,
    )
