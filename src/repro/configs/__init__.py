from repro.configs.registry import ARCHS, SHAPES, DIT_SHAPES, SUBQUADRATIC, cells, get, get_smoke
