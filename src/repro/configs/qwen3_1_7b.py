"""qwen3-1.7b [dense] — qk_norm, GQA (kv=8), no QKV bias.
28L d_model=2048 16H d_ff=6144 vocab=151936. [hf:Qwen/Qwen3; hf]
"""
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="qwen3-1.7b", family="dense",
        n_layers=28, d_model=2048, vocab=151936,
        attn_type="gqa", n_heads=16, n_kv_heads=8, head_dim=128,
        qkv_bias=False, qk_norm=True, rope_theta=1e6,
        d_ff=6144, mlp_act="swiglu",
        norm="rmsnorm", tie_embeddings=True, pos_embed="rope",
        max_seq=32768, dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="qwen3-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        attn_type="gqa", n_heads=4, n_kv_heads=2, head_dim=16,
        qk_norm=True, d_ff=128, mlp_act="swiglu",
        norm="rmsnorm", tie_embeddings=True, max_seq=1024,
    )
