"""Architecture registry: ``get(arch_id)`` -> full ModelCfg/DiTCfg;
``get_smoke(arch_id)`` -> reduced same-family config for CPU smoke tests.

Every entry matches the assigned public config exactly (see per-file
provenance comments). ``--arch <id>`` in the launchers resolves here.
"""
from __future__ import annotations

import importlib
from typing import Any, Dict

ARCHS = (
    "whisper-tiny", "mamba2-130m", "qwen2.5-3b", "qwen3-1.7b", "stablelm-3b",
    "qwen2.5-14b", "hymba-1.5b", "deepseek-v2-236b", "kimi-k2-1t-a32b",
    "chameleon-34b", "dit-xl-2",
)

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def _module(arch: str):
    if arch not in _MOD:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MOD[arch]}")


def get(arch: str, **overrides):
    cfg = _module(arch).full()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke(arch: str, **overrides):
    cfg = _module(arch).smoke()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


# ---------------------------------------------------------------------------
# assigned input shapes (LM family; per-arch applicability in launch/shapes)
# ---------------------------------------------------------------------------
SHAPES: Dict[str, dict] = {
    "train_4k":    {"kind": "train",   "seq": 4096,   "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768,  "batch": 32},
    "decode_32k":  {"kind": "decode",  "seq": 32768,  "batch": 128},
    "long_500k":   {"kind": "decode",  "seq": 524288, "batch": 1},
}

# archs with sub-quadratic token mixing run long_500k; pure full-attention
# archs skip it (assignment rule; DESIGN §6).
SUBQUADRATIC = {"mamba2-130m", "hymba-1.5b"}

# DiT-specific shape set (the paper's own model; extra beyond the 40 cells)
DIT_SHAPES: Dict[str, dict] = {
    "train_256":  {"kind": "dit_train",  "batch": 256},
    "sample_128": {"kind": "dit_sample", "batch": 128},
}


def cells(arch: str):
    """Valid (shape_id, meta) pairs for an arch (assignment matrix)."""
    if arch == "dit-xl-2":
        return list(DIT_SHAPES.items())
    out = []
    for sid, meta in SHAPES.items():
        if sid == "long_500k" and arch not in SUBQUADRATIC:
            continue
        out.append((sid, meta))
    return out
