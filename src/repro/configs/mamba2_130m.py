"""mamba2-130m [ssm] — attention-free SSD (state-space duality).
24L d_model=768 d_ff=0 vocab=50280 ssm_state=128. [arXiv:2405.21060; unverified]
"""
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, vocab=50280,
        attn_type="none", block_type="ssm_only", d_ff=0,
        ssm=True, d_inner=1536, ssm_state=128, ssm_head_dim=64,
        ssm_chunk=256, ssm_groups=1,
        norm="rmsnorm", tie_embeddings=True, pos_embed="none",
        max_seq=1 << 20, dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, vocab=256,
        attn_type="none", block_type="ssm_only", d_ff=0,
        ssm=True, d_inner=128, ssm_state=16, ssm_head_dim=32,
        ssm_chunk=8, ssm_groups=1,
        norm="rmsnorm", tie_embeddings=True, pos_embed="none", max_seq=4096,
    )
