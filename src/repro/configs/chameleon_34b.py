"""chameleon-34b [vlm] — early-fusion VLM backbone: VQ image tokens share
the 65536-token vocabulary (frontend STUB: inputs are token ids).
48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536, qk-norm.
[arXiv:2405.09818; unverified]
"""
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, vocab=65536,
        attn_type="gqa", n_heads=64, n_kv_heads=8, head_dim=128,
        qkv_bias=False, qk_norm=True, rope_theta=10000.0,
        d_ff=22016, mlp_act="swiglu",
        norm="rmsnorm", tie_embeddings=False, pos_embed="rope",
        max_seq=32768, dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="chameleon-smoke", family="vlm",
        n_layers=2, d_model=64, vocab=256,
        attn_type="gqa", n_heads=4, n_kv_heads=2, head_dim=16,
        qk_norm=True, d_ff=128, mlp_act="swiglu",
        norm="rmsnorm", tie_embeddings=False, max_seq=1024,
    )
