"""qwen2.5-14b [dense] — GQA (kv=8), QKV bias.
48L d_model=5120 40H d_ff=13824 vocab=152064. [hf:Qwen/Qwen2.5; hf]
"""
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="qwen2.5-14b", family="dense",
        n_layers=48, d_model=5120, vocab=152064,
        attn_type="gqa", n_heads=40, n_kv_heads=8, head_dim=128,
        qkv_bias=True, rope_theta=1e6,
        d_ff=13824, mlp_act="swiglu",
        norm="rmsnorm", tie_embeddings=False, pos_embed="rope",
        max_seq=32768, dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="qwen2.5-14b-smoke", family="dense",
        n_layers=2, d_model=96, vocab=256,
        attn_type="gqa", n_heads=6, n_kv_heads=2, head_dim=16,
        qkv_bias=True, d_ff=192, mlp_act="swiglu",
        norm="rmsnorm", tie_embeddings=False, max_seq=1024,
    )
