"""whisper-tiny [audio] — enc-dec, conv frontend STUB (precomputed frame
embeddings). 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
[arXiv:2212.04356; unverified]
"""
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="whisper-tiny", family="audio",
        n_layers=4, d_model=384, vocab=51865,
        attn_type="gqa", n_heads=6, n_kv_heads=6, head_dim=64,
        qkv_bias=True, d_ff=1536, mlp_act="gelu", mlp_bias=True,
        norm="layernorm", tie_embeddings=True, pos_embed="learned",
        encdec=True, n_enc_layers=4, enc_seq=1500,
        max_seq=32768, dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="whisper-tiny-smoke", family="audio",
        n_layers=2, d_model=64, vocab=256,
        attn_type="gqa", n_heads=4, n_kv_heads=4, head_dim=16,
        qkv_bias=True, d_ff=128, mlp_act="gelu", mlp_bias=True,
        norm="layernorm", tie_embeddings=True, pos_embed="learned",
        encdec=True, n_enc_layers=2, enc_seq=30, max_seq=128,
    )
