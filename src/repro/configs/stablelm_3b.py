"""stablelm-3b [dense] — MHA (kv=32).
32L d_model=2560 32H d_ff=6912 vocab=50304. [hf:stabilityai/stablelm; unverified]
"""
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, vocab=50304,
        attn_type="gqa", n_heads=32, n_kv_heads=32, head_dim=80,
        qkv_bias=False, rope_theta=10000.0,
        d_ff=6912, mlp_act="swiglu",
        norm="layernorm", tie_embeddings=False, pos_embed="rope",
        max_seq=32768, dtype="bfloat16",
    )


def smoke() -> ModelCfg:
    return ModelCfg(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        attn_type="gqa", n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, mlp_act="swiglu",
        norm="layernorm", tie_embeddings=False, max_seq=1024,
    )
